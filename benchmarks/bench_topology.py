"""Topology-aware hierarchical EP — two-level vs flat dispatch latency.

Compiles each skew scenario twice against a 2-node cluster (ep=8, 4 ranks
per node, 350 GB/s intra vs 50 GB/s inter links) and simulates both with
the *same* topology-aware cost model:

* **flat** — one put per nonzero (dst, expert) cell, every cross-node cell
  paying its own inter-node hop latency (the seed's dispatch, now priced
  on heterogeneous links);
* **hier** — two-level dispatch (``dispatch_mode="hier"``): latency-bound
  cross-node groups gather at a node-leader rank over the fast intra-node
  links and take the slow hop as one aggregated message; byte-bound groups
  stay on the direct path (``routing.aggregate_group``), keeping per-cell
  compute overlap.

The dispatch-to-combine win is gated: hier must strictly beat flat on at
least two of the three skew scenarios, otherwise the run fails (CI
regression gate for the topology stack). The int8-compressed inter-node
variant is emitted as context, as is the cost-model selector's pick —
gated only on *never* choosing a candidate predicted worse than the best
flat candidate (the never-worse-than-flat contract of the hier grid).
"""

from __future__ import annotations

from repro.core import autoselect
from repro.core.costmodel import CostModel
from repro.core.hardware import AscendA3, Topology
from repro.core.odg import ScheduleConfig, build_moe_ffn_forward
from repro.core.routing import hotspot_plan, node_limited_plan, skewed_plan
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_unified

from .common import emit

EP, E_LOC, ROWS = 8, 8, 16
D_MODEL, D_FF = 1024, 256
M_SPLIT = 4
TOPO = Topology(ranks_per_node=4, intra_gbps=350.0, inter_gbps=50.0,
                intra_hop_us=0.35, inter_hop_us=2.0)
PIPELINE = ["ratr", "hier_dispatch"]
WINS_REQUIRED = 2


def _cases():
    yield "zipf", skewed_plan(EP, E_LOC, ROWS, 1.6)
    yield "hotspot", hotspot_plan(EP, E_LOC, ROWS, background=2)
    yield "node_limited", node_limited_plan(EP, E_LOC, ROWS,
                                            node_size=TOPO.ranks_per_node)


def _cfg(plan, **kw) -> ScheduleConfig:
    return ScheduleConfig(ep=EP, e_loc=E_LOC, rows=0, d_model=D_MODEL,
                          d_ff=D_FF, gmm_m_split=M_SPLIT,
                          gmm_split_mode="source_aligned", plan=plan,
                          topology=TOPO, **kw)


def _d2c(cfg, hw, cost):
    s = compile_schedule(build_moe_ffn_forward(cfg), pipeline=PIPELINE)
    return simulate_unified(s, hw, cost=cost)


def run(hw: AscendA3 = AscendA3()) -> None:
    cost = CostModel(hw=hw, topology=TOPO)
    wins = 0
    for name, plan in _cases():
        flat = _d2c(_cfg(plan), hw, cost)
        hier = _d2c(_cfg(plan, dispatch_mode="hier"), hw, cost)
        hier_c = _d2c(_cfg(plan, dispatch_mode="hier",
                           xnode_compress="int8"), hw, cost)
        f, h = flat.dispatch_to_combine_us, hier.dispatch_to_combine_us
        win_pct = (f - h) / max(1e-9, f) * 100
        won = h < f
        wins += won
        emit(f"topology_{name}_flat", f,
             f"inter_busy={flat.link_us.get('inter', 0.0):.1f}us "
             f"intra_busy={flat.link_us.get('intra', 0.0):.1f}us")
        emit(f"topology_{name}_hier", h,
             f"win={win_pct:+.2f}% "
             f"inter_busy={hier.link_us.get('inter', 0.0):.1f}us "
             f"intra_busy={hier.link_us.get('intra', 0.0):.1f}us")
        emit(f"topology_{name}_hier_int8", hier_c.dispatch_to_combine_us,
             f"context=inter-node wire bytes halved "
             f"inter_busy={hier_c.link_us.get('inter', 0.0):.1f}us")

        # Selector contract: with a Topology in the config, auto-selection
        # prices flat and hier candidates on the same per-link-class model
        # and must never pick one predicted worse than the best flat.
        choice = autoselect.select(None, _cfg(plan))
        flat_best = min(s.predicted_us for s in choice.scores
                        if s.cfg.dispatch_mode == "flat")
        emit(f"topology_{name}_auto_pred", choice.predicted_us,
             f"pick={choice.tag} flat_best={flat_best:.1f}us")
        if choice.predicted_us > flat_best:
            raise RuntimeError(
                f"auto-selection picked {choice.tag} predicted at "
                f"{choice.predicted_us:.1f}us, worse than the best flat "
                f"candidate ({flat_best:.1f}us) on scenario {name!r}")
    emit("topology_scenario_wins", float(wins), f"required>={WINS_REQUIRED}of3")
    if wins < WINS_REQUIRED:
        raise RuntimeError(
            f"hierarchical dispatch beat flat on only {wins}/3 skew "
            f"scenarios (need >= {WINS_REQUIRED})")


if __name__ == "__main__":
    run()
