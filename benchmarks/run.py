"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (grading format).

    PYTHONPATH=src python -m benchmarks.run [--only moe_ffn,step,...]
"""

from __future__ import annotations

import argparse
import sys
import traceback

from .common import CSV_HEADER

SECTIONS = [
    ("moe_ffn", "Table 3 / Fig 7: Dispatch-to-Combine latency",
     "benchmarks.bench_moe_ffn"),
    ("step", "Fig 8: end-to-end training step",
     "benchmarks.bench_step"),
    ("swiglu_add", "Fig 9: SwiGLU+Add tile interleaving / L2 reuse",
     "benchmarks.bench_swiglu_add"),
    ("sched_overhead", "Fig 10: static vs dynamic scheduling",
     "benchmarks.bench_sched_overhead"),
    ("autoselect", "Cost-model-guided pipeline selection latency",
     "benchmarks.bench_autoselect"),
    ("imbalance", "Routing-skew sweep: unified vs baseline under load skew",
     "benchmarks.bench_imbalance"),
    ("dropless", "Dropless plan-keyed schedule reuse per bucket policy",
     "benchmarks.bench_dropless"),
    ("replay", "Decode-trace replay: bucket policies under serving traffic",
     "benchmarks.bench_replay"),
    ("fusion", "Cross-layer fusion: fused vs back-to-back fragment makespan",
     "benchmarks.bench_fusion"),
    ("topology", "Topology-aware hierarchical EP: two-level vs flat dispatch",
     "benchmarks.bench_topology"),
    ("elastic", "Elastic rescale path: remap / re-key / biased selection",
     "benchmarks.bench_elastic"),
    ("ep_modes", "EP mode comparison on the JAX system",
     "benchmarks.bench_ep_modes"),
    ("roofline", "TPU roofline table from the dry-run",
     "benchmarks.bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, metavar="NAME[,NAME...]",
                    help="comma-separated section names to run "
                         f"(choices: {','.join(k for k, *_ in SECTIONS)})")
    args = ap.parse_args()
    only = None
    if args.only:
        only = {name.strip() for name in args.only.split(",") if name.strip()}
        known = {k for k, *_ in SECTIONS}
        unknown = only - known
        if unknown:
            ap.error(f"unknown section(s) {sorted(unknown)}; "
                     f"choices: {sorted(known)}")

    print(CSV_HEADER)
    failed = []
    for key, title, module in SECTIONS:
        if only and key not in only:
            continue
        print(f"# --- {title} ---")
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
        except Exception as e:  # noqa: BLE001
            failed.append((key, e))
            traceback.print_exc(limit=4)
            print(f"{key}_FAILED,0,{e}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
