"""Table 3 / Figure 7 — Dispatch-to-Combine MoE-FFN latency, EP ∈ {4,8,16}.

Runs the *actual compiled schedules* (same objects the numerical executor
validates) through the discrete-event A3 model: the baseline is the
operator-by-operator collective path, HyperParallel-MoE is the unified
CTQ/VTQ taskflow with RATR + backward GMM interleaving.
"""

from __future__ import annotations

from repro.core.hardware import AscendA3
from repro.core.odg import build_moe_ffn_backward, build_moe_ffn_forward
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_baseline, simulate_unified

from .common import emit, opt_pipeline, paper_module_config, phase_summary

PAPER = {  # (baseline_ms, ours_ms) from Table 3
    (4, "fwd"): (16.3, 10.2), (4, "bwd"): (27.9, 19.4),
    (8, "fwd"): (17.3, 10.3), (8, "bwd"): (29.8, 19.6),
    (16, "fwd"): (18.4, 11.2), (16, "bwd"): (30.5, 19.9),
}


def run(hw: AscendA3 = AscendA3()) -> dict:
    out = {}
    for ep in (4, 8, 16):
        tot_b, tot_u = 0.0, 0.0
        for direction, tag in (("forward", "fwd"), ("backward", "bwd")):
            builder = (build_moe_ffn_forward if direction == "forward"
                       else build_moe_ffn_backward)
            base_cfg = paper_module_config(ep, m_split_mult=1)
            opt_cfg = paper_module_config(ep, m_split_mult=4)
            s_base = compile_schedule(builder(base_cfg))
            s_opt = compile_schedule(builder(opt_cfg),
                                     pipeline=opt_pipeline(direction))
            b = simulate_baseline(s_base, hw)
            u = simulate_unified(s_opt, hw)
            tot_b += b.makespan_us
            tot_u += u.makespan_us
            pb, pu = PAPER[(ep, tag)]
            emit(f"moe_ffn_ep{ep}_{tag}_baseline", b.makespan_us,
                 f"paper={pb}ms mac={b.mac_ratio:.2f}")
            emit(f"moe_ffn_ep{ep}_{tag}_hyperparallel", u.makespan_us,
                 f"paper={pu}ms mac={u.mac_ratio:.2f} "
                 f"speedup={b.makespan_us / u.makespan_us:.2f}x "
                 f"paper_speedup={pb / pu:.2f}x")
            emit(f"moe_ffn_ep{ep}_{tag}_d2c", u.dispatch_to_combine_us,
                 phase_summary(u))
            out[(ep, tag)] = (b, u)
        emit(f"moe_ffn_ep{ep}_total_speedup",
             0.0, f"{tot_b / tot_u:.2f}x (paper "
             f"{(PAPER[(ep, 'fwd')][0] + PAPER[(ep, 'bwd')][0]) / (PAPER[(ep, 'fwd')][1] + PAPER[(ep, 'bwd')][1]):.2f}x)")
    return out


if __name__ == "__main__":
    run()
