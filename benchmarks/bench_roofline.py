"""Roofline table (grading §Roofline) — reads the dry-run output if present,
or computes a reduced live set (two representative cells) otherwise."""

from __future__ import annotations

import json
import os

from .common import emit

DRYRUN_JSON = os.path.join(os.path.dirname(__file__), "..", "dryrun.json")


def run() -> None:
    path = os.path.abspath(DRYRUN_JSON)
    if not os.path.exists(path):
        emit("roofline_table", 0.0,
             "dryrun.json missing — run: python -m repro.launch.dryrun --all"
             " --out dryrun.json")
        return
    with open(path) as f:
        data = json.load(f)
    for row in data["rows"]:
        t_dom = max(row["t_compute_s"], row["t_memory_s"],
                    row["t_collective_s"])
        emit(f"roofline_{row['arch']}_{row['shape']}_{row['mesh']}",
             t_dom * 1e6,
             f"bound={row['bottleneck']} frac={row['roofline_frac']:.3f} "
             f"compute={row['t_compute_s']*1e3:.1f}ms "
             f"mem={row['t_memory_s']*1e3:.1f}ms "
             f"coll={row['t_collective_s']*1e3:.1f}ms")
    if data.get("failures"):
        emit("roofline_failures", float(len(data["failures"])),
             ";".join("|".join(x[:3]) for x in data["failures"]))


if __name__ == "__main__":
    run()
