"""Figure 10 — static vs dynamic scheduling overhead on taskized SwiGLU+Add.

Both paths run the *same* tile taskflow with the same event dependencies;
the only difference is the per-task dispatch cost on the device critical
path: 0.1 µs (precompiled SSC consumption) vs 2.36 µs (online dependency
checking + task selection) — the paper's measured §6.2 numbers.
"""

from __future__ import annotations

from repro.core.hardware import AscendA3
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_unified

from .common import build_swiglu_add_odg, emit

PAPER = {2048: (413.00, 54.00), 32768: (862.80, 588.38)}


def run(hw: AscendA3 = AscendA3()) -> None:
    for M in (2048, 8192, 32768):
        n_tiles = 128                # fixed fine AIV tiling (§6.2 regime)
        static = simulate_unified(
            compile_schedule(build_swiglu_add_odg(M, n_tiles)), hw,
            dispatch_overhead_us=hw.static_dispatch_us)
        dyn = simulate_unified(
            compile_schedule(build_swiglu_add_odg(M, n_tiles)), hw,
            dispatch_overhead_us=hw.dynamic_dispatch_us,
            serialize_dispatch=True)
        derived = (f"static={static.makespan_us:.1f}us "
                   f"ratio={dyn.makespan_us / static.makespan_us:.2f}x")
        if M in PAPER:
            pd, ps = PAPER[M]
            derived += f" paper:{pd:.0f}us/{ps:.0f}us={pd / ps:.2f}x"
        emit(f"sched_overhead_M{M}_dynamic", dyn.makespan_us, derived)


if __name__ == "__main__":
    run()
