"""Batched serving example: prefill a batch of prompts, decode N tokens.

Covers the inference path the decode_32k / long_500k dry-run cells lower:
KV-cache prefill → sequential one-token decode steps (greedy).

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch mamba2-1.3b]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    help="any non-encoder arch id (smoke-scaled)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab)
    max_len = P + args.gen

    prefill = jax.jit(lambda p, b: M.prefill(cfg, p, b, max_len=max_len))
    decode = jax.jit(lambda p, t, c: M.decode_step(cfg, p, t, c))

    t0 = time.perf_counter()
    last, cache = prefill(params, {"tokens": prompts})
    jax.block_until_ready(last)
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)

    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={P} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f}ms   "
          f"decode: {t_decode/max(1, args.gen-1)*1e3:.2f}ms/token "
          f"(incl. first-call compile)")
    for b in range(min(B, 2)):
        print(f"  seq{b}: {prompts[b, -6:].tolist()} → {gen[b].tolist()}")


if __name__ == "__main__":
    main()
