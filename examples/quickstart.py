"""Quickstart: the three layers of HyperParallel-MoE-JAX in two minutes.

1. Compile a MoE-FFN fragment into a static CTQ/VTQ taskflow (SSC).
2. Validate the schedule numerically against the monolithic reference.
3. Train a tiny MoE model a few steps with the standard substrate.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.odg import ScheduleConfig, build_moe_ffn_forward
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_baseline, simulate_unified
from repro.core import executor as ex
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.models import model as M
from repro.optim import adamw

# --- 1. compile a schedule ------------------------------------------------
cfg = ScheduleConfig(ep=4, e_loc=4, rows=64, d_model=512, d_ff=256,
                     gmm_m_split=8)
sched = compile_schedule(build_moe_ffn_forward(cfg), pipeline=["ratr"])
print(f"compiled taskflow: {sched.n_tasks} tile tasks, "
      f"{len(sched.events)} events, "
      f"CTQ[0]={len(sched.queue(0, 'CTQ'))} VTQ[0]={len(sched.queue(0, 'VTQ'))}")

# --- 2. numerical validation + simulated speedup ---------------------------
x_src, w1, w2 = ex.make_inputs(cfg)
st = ex.ExecutorState(cfg)
ex.load_forward_state(cfg, st, x_src, w1, w2)
ex.execute(sched, st, rng=np.random.default_rng(0))
ref = ex.reference_forward(cfg, x_src, w1, w2)
np.testing.assert_allclose(
    np.stack([st.get("y_ret", r) for r in range(cfg.ep)]), ref["y_ret"],
    rtol=1e-5, atol=1e-5)
print("executor == monolithic reference ✓")

base = simulate_baseline(compile_schedule(build_moe_ffn_forward(
    ScheduleConfig(ep=4, e_loc=4, rows=64, d_model=512, d_ff=256))))
uni = simulate_unified(sched)
print(f"simulated D2C: baseline {base.makespan_us:.0f}us → "
      f"unified {uni.makespan_us:.0f}us "
      f"({base.makespan_us / uni.makespan_us:.2f}x)")

# --- 2b. dropless: compile from real router output, reuse via buckets ------
from repro.core.ssc import SSCCache
from repro.models.moe import MoEConfig, init_moe, plan_from_routing, \
    router_topk

mc = MoEConfig(n_experts=8, top_k=2, d_expert=16)
moe_params = init_moe(jax.random.PRNGKey(2), 64, mc)
cache = SSCCache(max_entries=16)
for step in range(3):
    xb = jax.random.normal(jax.random.PRNGKey(10 + step), (128, 64))
    _, top_i = router_topk(moe_params["router"], xb, mc)
    # capacity=None → dropless; bucket_rows quantizes the plan so jittered
    # batches share one SSC cache entry instead of recompiling every step.
    bridge = plan_from_routing(np.asarray(top_i), mc, 4, capacity=None,
                               bucket_rows=32)
    cfg_d = ScheduleConfig(ep=4, e_loc=2, rows=0, d_model=64, d_ff=16,
                           plan=bridge.plan)
    cache.get_or_compile(cfg_d, "forward", pipeline=["ratr"])
print(f"dropless cache after 3 jittered batches: {cache.info()}")

# --- 3. train a tiny MoE model ---------------------------------------------
mcfg = get_smoke_config("granite-moe-3b-a800m")
params = adamw.cast_params(M.init_params(mcfg, jax.random.PRNGKey(0)),
                           mcfg.compute_dtype)
opt_state = adamw.init_opt_state(params)
oc = adamw.OptConfig(lr=3e-3, warmup_steps=5, total_steps=50,
                     weight_decay=0.0)
stream = SyntheticStream(DataConfig(vocab=mcfg.vocab, seq_len=32,
                                    global_batch=8))


@jax.jit
def step(params, opt_state, batch):
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(mcfg, p, batch))(params)
    p2, s2, m = adamw.apply_updates(params, grads, opt_state, oc)
    return p2, s2, loss


for i in range(20):
    batch = {k: jnp.asarray(v)
             for k, v in stream.global_batch_np(i).items()}
    params, opt_state, loss = step(params, opt_state, batch)
    if i % 5 == 0:
        print(f"step {i:3d} loss {float(loss):.4f}")
print("quickstart complete.")
