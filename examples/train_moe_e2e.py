"""End-to-end MoE training driver: ~100M-param model, few hundred steps,
with the full production substrate — data pipeline, mixed-precision AdamW,
checkpointing, auto-resume, straggler watchdog.

Run:  PYTHONPATH=src python examples/train_moe_e2e.py \
          [--steps 300] [--ckpt-dir /tmp/moe_e2e]

(Scaled to CPU: d_model 256, 8 experts, ~100M params via vocab+experts.
On a real TPU mesh this same driver runs the full granite/dbrx configs —
see repro/launch/dryrun.py for the mesh plumbing.)
"""

import argparse
import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.ft.runner import FTConfig, train_loop
from repro.models import model as M
from repro.models.moe import MoEConfig
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/moe_e2e_ckpt")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_smoke_config("granite-moe-3b-a800m"),
        name="moe-100m", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
        vocab=32000, vocab_pad=128,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=512),
        remat=False)
    n = cfg.param_count()
    print(f"model: {cfg.name}  params={n/1e6:.1f}M")

    params = adamw.cast_params(M.init_params(cfg, jax.random.PRNGKey(0)),
                               cfg.compute_dtype)
    opt_state = adamw.init_opt_state(params)
    oc = adamw.OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch))(params)
        p2, s2, m = adamw.apply_updates(params, grads, opt_state, oc)
        m["loss"] = loss
        return p2, s2, m

    class _Stream:
        def __init__(self):
            self.s = SyntheticStream(DataConfig(
                vocab=cfg.vocab, seq_len=args.seq,
                global_batch=args.batch))

        def sharded_batch(self, step, mesh, sharding):
            import jax.numpy as jnp
            return {k: jnp.asarray(v)
                    for k, v in self.s.global_batch_np(step).items()}

    run = train_loop(
        step_fn=step_fn, params=params, opt_state=opt_state,
        stream=_Stream(), mesh=None, batch_sharding=None,
        n_steps=args.steps,
        ft=FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50))

    if run.resumed_from is not None:
        print(f"(auto-resumed from step {run.resumed_from})")
    for m in run.metrics_log:
        print(f"step {m['step']:4d} loss {m['loss']:.4f} "
              f"gnorm {m['grad_norm']:.3f} {m['step_time_s']*1e3:.0f}ms")
    if run.stragglers:
        print(f"straggler events: {run.stragglers}")
    first, last = run.metrics_log[0]["loss"], run.metrics_log[-1]["loss"]
    print(f"loss {first:.3f} → {last:.3f} over {run.step} steps "
          f"({'OK' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
