"""Schedule explorer: inspect what the static scheduler actually builds.

Compiles the paper's EP8 module, prints per-rank queue heads, the event
table, a simulated Gantt summary, and dumps the per-rank SSC to JSON —
the artifact a device runtime would consume (§5.1).

Run:  PYTHONPATH=src python examples/schedule_explorer.py [--ep 8]
"""

import argparse
import collections
import json

from repro.core.odg import build_moe_ffn_backward, build_moe_ffn_forward
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_baseline, simulate_unified
from repro.core.ssc import rank_view, schedule_to_ssc

import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
from common import paper_module_config  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ep", type=int, default=8)
    ap.add_argument("--dump", default="/tmp/ssc_rank0.json")
    args = ap.parse_args()

    cfg = paper_module_config(args.ep, m_split_mult=4)
    fwd = compile_schedule(build_moe_ffn_forward(cfg), pipeline=["ratr"])
    bwd = compile_schedule(build_moe_ffn_backward(cfg),
                           pipeline=["ratr", "gmm_interleave"])

    for name, s in (("forward", fwd), ("backward", bwd)):
        print(f"\n=== {name}: {s.n_tasks} tasks, {len(s.events)} events ===")
        ctq = s.queue(0, "CTQ")
        vtq = s.queue(0, "VTQ")
        print(f"rank0 CTQ[{len(ctq)}] head: "
              + " ".join(s.tasks[t].op_name.split('@')[0] for t in ctq[:6]))
        print(f"rank0 VTQ[{len(vtq)}] head: "
              + " ".join(f"{s.tasks[t].op_name.split('@')[0]}"
                         f"→{s.tasks[t].dst_rank}" for t in vtq[:6]))
        thr = collections.Counter(e.threshold for e in s.events.values())
        print(f"event thresholds: {dict(sorted(thr.items()))}")
        blob = schedule_to_ssc(s)
        print(f"SSC size: {len(blob) / 1024:.1f} KiB "
              f"({len(blob) // max(1, s.n_tasks)} B/task)")
        base_cfg = paper_module_config(args.ep, m_split_mult=1)
        builder = (build_moe_ffn_forward if name == "forward"
                   else build_moe_ffn_backward)
        b = simulate_baseline(compile_schedule(builder(base_cfg)))
        u = simulate_unified(s)
        print(f"simulated D2C: baseline {b.makespan_us/1e3:.2f}ms → "
              f"unified {u.makespan_us/1e3:.2f}ms "
              f"({b.makespan_us/u.makespan_us:.2f}x)  "
              f"MAC {b.mac_ratio:.2f}→{u.mac_ratio:.2f}")

    with open(args.dump, "w") as f:
        json.dump(rank_view(fwd, 0), f, indent=1)
    print(f"\nper-rank SSC (rank 0, forward) dumped to {args.dump}")


if __name__ == "__main__":
    main()
